"""Pooled fused WU graph (kfac.apply_updates(wu_plan=...)): plan
invariants, bitwise parity with the legacy per-leaf path across dense /
MoE-stacked / shared-A / padded specs, the fused_precond kernel vs its
oracle, per-path optimizer-state slimming, and the fused INV→VMM
solver's local image. The forced-multi-device parity lives in
tests/test_wu_fusion_multidev.py."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.core import kfac
from repro.core.kfac import KFACConfig
from repro.core.soi import LinearSpec
from repro.dist.api import path_key
from repro.launch import steps as steps_mod
from repro.solve import make_wu_plan, refresh_and_precondition

KCFG = KFACConfig(block_size=16, ns_iters=6, taylor_terms=2,
                  refine_steps=1)

# dense + shared-A + stacked + padded (d % bs != 0) + MoE-style stack:
# every geometry the plan/pool machinery must handle
SPECS = {
    "w1": LinearSpec(d_in=32, d_out=16),
    "w2": LinearSpec(d_in=32, d_out=16, share_a_with="w1"),
    "stk/w": LinearSpec(d_in=16, d_out=20, stack=(3,)),      # padded
    "moe/wg": LinearSpec(d_in=16, d_out=16, stack=(2, 2)),
    "moe/wu": LinearSpec(d_in=16, d_out=16, stack=(2, 2),
                         share_a_with="moe/wg"),
}


def _params():
    return {
        "w1": jnp.zeros((32, 16)),
        "w2": jnp.zeros((32, 16)),
        "stk": {"w": jnp.zeros((3, 16, 20))},
        "moe": {"wg": jnp.zeros((2, 2, 16, 16)),
                "wu": jnp.zeros((2, 2, 16, 16))},
        "bias": jnp.zeros((7,)),                 # first-order path
    }


def _spd(r, shape):
    bs = shape[-1]
    a = r.standard_normal(shape[:-1] + (2 * bs,)).astype(np.float32)
    return jnp.asarray(np.einsum("...ij,...kj->...ik", a, a) / (2 * bs))


def _state(seed=0):
    r = np.random.default_rng(seed)
    params = _params()
    state = kfac.init(params, SPECS, KCFG)
    state = state._replace(
        factors=jax.tree.map(lambda x: _spd(r, x.shape), state.factors))
    state = jax.jit(lambda s: kfac.refresh_inverses(s, KCFG))(state)
    grads = jax.tree.map(
        lambda p: jnp.asarray(r.standard_normal(p.shape), jnp.float32),
        params)
    return params, grads, state


def _assert_tree_bitwise(a, b):
    fa = jax.tree_util.tree_flatten_with_path(a)[0]
    fb = {jax.tree_util.keystr(p): v for p, v in
          jax.tree_util.tree_flatten_with_path(b)[0]}
    assert len(fa) == len(fb)
    for p, v in fa:
        np.testing.assert_array_equal(
            np.asarray(v), np.asarray(fb[jax.tree_util.keystr(p)]),
            err_msg=jax.tree_util.keystr(p))


# ---------------------------------------------------------------------------
# plan invariants
# ---------------------------------------------------------------------------

def test_wu_plan_covers_every_tile_once():
    _, _, state = _state()
    for ndev in (1, 3, 4):
        wu = make_wu_plan(SPECS, state.factors, KCFG, ndev=ndev)
        # every factored leaf appears in exactly one tile group and one
        # stacked group, with the tile count its geometry implies
        tile_names = [l.name for g in wu.groups for l in g.leaves]
        stack_names = [m.name for s in wu.stacked for m in s.members]
        assert sorted(tile_names) == sorted(SPECS)
        assert sorted(stack_names) == sorted(SPECS)
        for g in wu.groups:
            n = g.n_tiles
            assert g.a_src.shape == g.g_src.shape == (n,)
            # tiles device-major: every tile exactly once, pads are -1
            for slots, back in ((g.slots, g.gather_back),
                                (g.g_slots, g.g_gather_back)):
                real = slots[slots >= 0]
                assert sorted(real.tolist()) == list(range(n))
                m = slots.shape[1]
                for t, pos in enumerate(back.tolist()):
                    assert slots[pos // m, pos % m] == t
        # a_src/g_src address blocks inside the embedded INV plan pools
        by_bs = {p.bs: sum(p.leaf_counts) for p in wu.inv_plan.groups}
        for g in wu.groups:
            assert g.a_src.max() < by_bs[g.bi]
            assert g.g_src.max() < by_bs[g.bo]


def test_wu_plan_from_abstract_shapes():
    _, _, state = _state()
    ab = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state.factors)
    pa = make_wu_plan(SPECS, ab, KCFG, ndev=4)
    pb = make_wu_plan(SPECS, state.factors, KCFG, ndev=4)
    for ga, gb in zip(pa.groups, pb.groups):
        np.testing.assert_array_equal(ga.a_src, gb.a_src)
        np.testing.assert_array_equal(ga.slots, gb.slots)


def test_wu_plan_pool_bytes_cap():
    _, _, state = _state()
    tiny = make_wu_plan(SPECS, state.factors, KCFG, ndev=1,
                        pool_bytes_cap=0)
    assert all(not s.pooled for s in tiny.stacked)
    big = make_wu_plan(SPECS, state.factors, KCFG, ndev=1)
    assert any(s.pooled for s in big.stacked)


def test_precondition_rejects_stale_plan():
    """A plan built for a narrower spec set must fail loudly instead
    of passing raw gradients through for the uncovered leaves."""
    params, grads, state = _state()
    narrow = {k: v for k, v in SPECS.items() if k != "w1"}
    # w2 shares w1's A, so drop it too to keep the narrow plan valid
    narrow.pop("w2")
    wu = make_wu_plan(narrow, state.factors, KCFG, ndev=1)
    with pytest.raises(ValueError, match="does not cover"):
        kfac.precondition(grads, state, SPECS, KCFG, wu_plan=wu)


def test_wu_plan_rejects_mismatched_inv_plan():
    from repro.solve import make_plan

    _, _, state = _state()
    inv = make_plan(state.factors, 2, KCFG)
    with pytest.raises(ValueError, match="devices"):
        make_wu_plan(SPECS, state.factors, KCFG, ndev=4, inv_plan=inv)


# ---------------------------------------------------------------------------
# bitwise parity: pooled fused vs legacy per-leaf
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("ndev", [1, 4])
def test_precondition_pooled_bitwise(ndev):
    params, grads, state = _state()
    wu = make_wu_plan(SPECS, state.factors, KCFG, ndev=ndev)
    ref = jax.jit(
        lambda g, s: kfac.precondition(g, s, SPECS, KCFG))(grads, state)
    got = jax.jit(
        lambda g, s: kfac.precondition(g, s, SPECS, KCFG, wu_plan=wu))(
            grads, state)
    _assert_tree_bitwise(ref, got)


@pytest.mark.parametrize("pool_elementwise", [False, True])
def test_apply_updates_pooled_bitwise(pool_elementwise):
    """Params AND the full optimizer state (momentum / Adam moments /
    step) must match the per-leaf reference bit for bit — the clip
    scale nu folds the same per-leaf dots in the same order."""
    params, grads, state = _state()
    wu = make_wu_plan(SPECS, state.factors, KCFG, ndev=1)
    p_ref, s_ref = jax.jit(lambda p, g, s: kfac.apply_updates(
        p, g, s, SPECS, KCFG))(params, grads, state)
    p_got, s_got = jax.jit(lambda p, g, s: kfac.apply_updates(
        p, g, s, SPECS, KCFG, wu_plan=wu,
        pool_elementwise=pool_elementwise))(params, grads, state)
    _assert_tree_bitwise(p_ref, p_got)
    _assert_tree_bitwise(s_ref.momentum, s_got.momentum)
    _assert_tree_bitwise(s_ref.adam_mu, s_got.adam_mu)
    _assert_tree_bitwise(s_ref.adam_nu, s_got.adam_nu)
    assert int(s_got.step) == int(s_ref.step)


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "moonshot-v1-16b-a3b"])
def test_train_step_fused_bitwise_on_arch(arch):
    """The launch-layer wiring: make_train_step(wu_plan=...) on real
    smoke archs (dense + MoE-stacked) is bitwise the legacy step."""
    cfg = get_smoke_config(arch)
    kcfg = KFACConfig(block_size=32, ns_iters=4, taylor_terms=2,
                      refine_steps=1, stats_batch=2, stats_seq=16)
    mod = steps_mod.model_module(cfg)
    specs = steps_mod.kfac_specs(cfg)
    params = mod.init(cfg, jax.random.PRNGKey(0))
    state = kfac.init(params, specs, kcfg)
    r = np.random.default_rng(0)
    state = state._replace(
        factors=jax.tree.map(lambda x: _spd(r, x.shape), state.factors))
    state = jax.jit(lambda s: kfac.refresh_inverses(s, kcfg))(state)
    tstate = steps_mod.TrainState(params, state)
    batch = {"tokens": jnp.asarray(
        r.integers(0, cfg.vocab, (2, 16)), jnp.int32)}

    wu = steps_mod.make_wu_plan_for(cfg, kcfg)
    s_ref, m_ref = jax.jit(
        steps_mod.make_train_step(cfg, kcfg))(tstate, batch)
    s_got, m_got = jax.jit(
        steps_mod.make_train_step(cfg, kcfg, wu_plan=wu))(tstate, batch)
    _assert_tree_bitwise(s_ref.params, s_got.params)
    _assert_tree_bitwise(s_ref.kfac.momentum, s_got.kfac.momentum)
    assert float(m_ref["loss"]) == float(m_got["loss"])


def test_fused_wu_local_refresh_and_precondition_bitwise():
    """solve.refresh_and_precondition without a mesh: the single-
    process image of the fused INV→VMM program matches replicated
    refresh + legacy precondition bitwise."""
    params, grads, state = _state()
    wu = make_wu_plan(SPECS, state.factors, KCFG, ndev=1)
    gbn = {path_key(p): g for p, g in
           jax.tree_util.tree_flatten_with_path(grads)[0]
           if path_key(p) in SPECS}
    inv, pre = jax.jit(lambda f, g: refresh_and_precondition(
        f, g, KCFG, wu))(state.factors, gbn)
    _assert_tree_bitwise(state.inverses, inv)
    ref = jax.jit(
        lambda g, s: kfac.precondition(g, s, SPECS, KCFG))(grads, state)
    ref_by = {path_key(p): v for p, v in
              jax.tree_util.tree_flatten_with_path(ref)[0]}
    for name in gbn:
        np.testing.assert_array_equal(
            np.asarray(pre[name]), np.asarray(ref_by[name]),
            err_msg=name)


# ---------------------------------------------------------------------------
# fused_precond Pallas kernel vs oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(5, 16, 8), (3, 128, 64),
                                   (2, 130, 200)])
def test_fused_precond_kernel_matches_oracle(shape):
    from repro.kernels import fused_precond
    from repro.kernels.ref import exact_two_sided, fused_precond_ref

    n, bi, bo = shape
    r = np.random.default_rng(0)
    a = jnp.asarray(r.standard_normal((n, bi, bi)), jnp.float32)
    g = jnp.asarray(r.standard_normal((n, bi, bo)), jnp.float32)
    gi = jnp.asarray(r.standard_normal((n, bo, bo)), jnp.float32)
    out, dots = fused_precond(a, g, gi)
    ref_out, ref_dots = fused_precond_ref(a, g, gi)
    # tiles: identical hi/lo partial-product set => bitwise
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref_out))
    # in-pass dot: the kernel reduces over the padded tile (zero pads),
    # so association can differ from the oracle's unpadded reduce at
    # the float level on non-aligned shapes
    np.testing.assert_allclose(np.asarray(dots), np.asarray(ref_dots),
                               rtol=1e-4, atol=1e-2)
    # and the bit-sliced path tracks the exact fp32 product
    ex = np.asarray(exact_two_sided(a, g, gi))
    rel = np.max(np.abs(np.asarray(out) - ex)) / np.max(np.abs(ex))
    assert rel < 1e-4


def test_precondition_kernel_path_allclose():
    """precondition(use_kernel=True) routes the tile-indexed pools
    through the Pallas program (interpret mode here): allclose to the
    einsum path — not bitwise, the kernel's matmuls are hi/lo
    bit-sliced — across the same mixed specs."""
    params, grads, state = _state()
    wu = make_wu_plan(SPECS, state.factors, KCFG, ndev=1)
    ref = jax.jit(
        lambda g, s: kfac.precondition(g, s, SPECS, KCFG))(grads, state)
    got = kfac.precondition(grads, state, SPECS, KCFG, wu_plan=wu,
                            use_kernel=True)
    for (p, a), b in zip(jax.tree_util.tree_flatten_with_path(ref)[0],
                         jax.tree.leaves(got)):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4,
            err_msg=jax.tree_util.keystr(p))


def test_fused_precond_dot_is_trust_region_mass():
    from repro.kernels import fused_precond

    r = np.random.default_rng(1)
    a = jnp.asarray(r.standard_normal((4, 16, 16)), jnp.float32)
    g = jnp.asarray(r.standard_normal((4, 16, 16)), jnp.float32)
    gi = jnp.asarray(r.standard_normal((4, 16, 16)), jnp.float32)
    out, dots = fused_precond(a, g, gi)
    want = np.asarray(jnp.sum(out * g, axis=(-2, -1)))
    np.testing.assert_allclose(np.asarray(dots), want, rtol=1e-5,
                               atol=1e-4)


# ---------------------------------------------------------------------------
# per-path optimizer-state slimming
# ---------------------------------------------------------------------------

def test_state_moments_allocated_per_path():
    params = _params()
    state = kfac.init(params, SPECS, KCFG)
    flat = {path_key(p): v for p, v in
            jax.tree_util.tree_flatten_with_path(state.momentum)[0]}
    mu = {path_key(p): v for p, v in
          jax.tree_util.tree_flatten_with_path(state.adam_mu)[0]}
    for name, p in jax.tree_util.tree_flatten_with_path(params)[0]:
        key = path_key(name)
        if key in SPECS:
            assert flat[key].shape == p.shape
            assert mu[key].shape == (0,)          # placeholder
        else:
            assert flat[key].shape == (0,)
            assert mu[key].shape == p.shape
    # treedef is preserved: state trees zip against params trees
    assert (jax.tree_util.tree_structure(state.momentum)
            == jax.tree_util.tree_structure(params))
    p_bytes = sum(np.asarray(x).nbytes for x in jax.tree.leaves(params))
    m_bytes = sum(
        np.asarray(x).nbytes
        for t in (state.momentum, state.adam_mu, state.adam_nu)
        for x in jax.tree.leaves(t))
    assert m_bytes < 3 * p_bytes


def test_state_slim_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint import store

    params, grads, state = _state()
    p2, s2 = kfac.apply_updates(params, grads, state, SPECS, KCFG)
    store.save(str(tmp_path), 1, s2)
    restored, _ = store.restore(str(tmp_path), s2)
    _assert_tree_bitwise(s2.momentum, restored.momentum)
    _assert_tree_bitwise(s2.adam_mu, restored.adam_mu)
