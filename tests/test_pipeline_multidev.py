"""Multi-device pipeline execution parity (forced 4-device mesh).

The acceptance contract, operationalized:

* **per-step equivalence at the fp32 floor** — on identical state the
  pipelined (pp=2) gradients match the monolithic accumulation path
  leaf-by-leaf to ~1e-5 relative (measured ~3e-7, pure f32 rounding of
  two different XLA programs computing the same math);
* **schedule independence** — gpipe and 1f1b drive the *same* program
  pieces through different tick orders and must produce identical
  losses (they agree bitwise in practice: grads are summed in
  microbatch order under both);
* **20-step loss-trajectory tracking** — tight (2e-4) over the first 8
  steps; 2e-2 over all 20. The widening is measured chaos: training
  dynamics amplify the per-step 3e-7 rounding floor by ~3-4x/step, so
  *any* two distinct-but-equivalent programs decorrelate to the loss-
  fluctuation scale by ~step 15 (EXPERIMENTS.md §Perf 5.3 records the
  sweep; the per-step grad bound above is the sharp statement of
  correctness).

The unmarked subprocess smoke keeps this coverage inside tier-1; the
multidevice CI job runs the marked tests directly.
"""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.core import kfac as kfac_mod
from repro.core.kfac import KFACConfig
from repro.dist.api import path_key
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_pipeline_mesh
from repro.launch.steps import TrainState
from repro.pipeline import (
    make_pipeline_grads_fn,
    make_schedule,
    partition_stages,
    split_microbatches,
)

M, B, T, STEPS = 4, 8, 16, 20
KCFG = KFACConfig(block_size=32, stats_batch=4, stats_seq=16)


def _setup(arch="qwen1.5-0.5b", dtype="float32"):
    cfg = dataclasses.replace(get_smoke_config(arch), dtype=dtype,
                              train_accum=M)
    mod = steps_mod.model_module(cfg)
    params = mod.init(cfg, jax.random.PRNGKey(0))
    specs = steps_mod.kfac_specs(cfg)
    r = np.random.default_rng(0)
    batches = [{"tokens": jnp.asarray(
        r.integers(0, cfg.vocab, (B, T)), jnp.int32)}
        for _ in range(STEPS)]
    return cfg, params, specs, batches


def _run_traj(cfg, params, specs, batches, step_fn):
    """20 steps with the full K-FAC cadence (stats+inv every 5)."""
    stats = jax.jit(steps_mod.make_stats_step(cfg, KCFG))
    inv = jax.jit(steps_mod.make_inv_step(cfg, KCFG))
    st = TrainState(params, kfac_mod.init(params, specs, KCFG))
    losses = []
    for i, b in enumerate(batches):
        if i % 5 == 0:
            st, _ = stats(st, b)
            st = inv(st)
        st, m = step_fn(st, b)
        losses.append(float(m["loss"]))
    return np.array(losses)


def _accum_ref(cfg, params, micro, n_micro):
    """Meshless gradient-accumulation baseline (the monolithic path)."""
    mod = steps_mod.model_module(cfg)

    def loss_of(p, b):
        return mod.loss_fn(cfg, p, b)[0]

    def accum_grads(p):
        g = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), p)
        tot = jnp.zeros((), jnp.float32)
        for m in range(n_micro):
            mb = jax.tree.map(lambda v: v[m], micro)
            l, gm = jax.value_and_grad(loss_of)(p, mb)
            g = jax.tree.map(lambda a, x: a + x / n_micro, g, gm)
            tot = tot + l / n_micro
        return tot, g

    return jax.jit(accum_grads)(params)


def _assert_grads_close(g_ref, g_pipe, tol=1e-5):
    fb = {path_key(p): v for p, v in
          jax.tree_util.tree_flatten_with_path(g_pipe)[0]}
    for p, v in jax.tree_util.tree_flatten_with_path(g_ref)[0]:
        k = path_key(p)
        a, b = np.asarray(v), np.asarray(fb[k])
        assert a.shape == b.shape, k
        scale = max(np.abs(a).max(), 1e-12)
        assert np.abs(a - b).max() / scale < tol, k


def _pipeline_grads(cfg, params, micro, mesh, n_micro, **part_kw):
    part = partition_stages(cfg, 2, **part_kw)
    sched = make_schedule("1f1b", 2, n_micro)
    fn = make_pipeline_grads_fn(cfg, part, sched, mesh)
    with jax.set_mesh(mesh):
        return jax.jit(fn)(params, micro)


@pytest.mark.multidevice
def test_pp2_grads_match_accum_at_fp32_floor():
    """Pipelined gradients == accumulation gradients, leaf by leaf, at
    the f32 rounding floor — the sharp per-step equivalence."""
    cfg, params, specs, batches = _setup()
    micro = split_microbatches(batches[0], M)
    l1, g1 = _accum_ref(cfg, params, micro, M)
    l2, g2 = _pipeline_grads(cfg, params, micro, make_pipeline_mesh(2),
                             M, require_uniform=True)
    assert abs(float(l1) - float(l2)) < 1e-5
    _assert_grads_close(g1, g2)


@pytest.mark.multidevice
def test_pp2_mp2_grads_match_model_only_baseline():
    """The tentpole parity gate: a forced (stage=2, data=1, model=2)
    mesh — megatron TP inside the stage program (sharded qkv/o and
    mlp, manual psums over the bound ``model`` axis) — reproduces the
    meshless accumulation gradients leaf-by-leaf at the f32 floor."""
    cfg, params, specs, batches = _setup()       # qwen: h=kv=4, ff=128
    micro = split_microbatches(batches[0], M)
    l1, g1 = _accum_ref(cfg, params, micro, M)
    mesh = make_pipeline_mesh(2, model=2)
    assert dict(mesh.shape) == {"stage": 2, "data": 1, "model": 2}
    l2, g2 = _pipeline_grads(cfg, params, micro, mesh, M,
                             require_uniform=True)
    assert abs(float(l1) - float(l2)) < 1e-5
    _assert_grads_close(g1, g2)


@pytest.mark.multidevice
def test_pp2_mp2_moe_ep_in_stage_parity():
    """EP-in-stage == portable dispatch: with data=1 the per-shard
    expert queues see the same tokens in the same order as the global
    scatter reference, so the (stage=2, model=2) program — experts
    sliced over ``model``, dispatch via _local_moe's manual
    collectives — matches the meshless path at the f32 floor."""
    cfg = dataclasses.replace(get_smoke_config("phi3.5-moe-42b-a6.6b"),
                              dtype="float32", train_accum=2)
    mod = steps_mod.model_module(cfg)
    params = mod.init(cfg, jax.random.PRNGKey(0))
    r = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        r.integers(0, cfg.vocab, (4, 16)), jnp.int32)}
    micro = split_microbatches(batch, 2)
    l1, g1 = _accum_ref(cfg, params, micro, 2)
    mesh = make_pipeline_mesh(2, model=2)
    l2, g2 = _pipeline_grads(cfg, params, micro, mesh, 2,
                             require_uniform=True)
    assert abs(float(l1) - float(l2)) / abs(float(l1)) < 1e-5
    _assert_grads_close(g1, g2)


@pytest.mark.multidevice
def test_pp2_nonuniform_hybrid_grads():
    """Non-uniform hybrid end-to-end: 3 pattern units + 1 ragged tail
    sublayer on 2 stages — (2, 1) unit split via padding + masks, tail
    + head on the last stage, MLPs TP-sharded (kv=1 keeps attention
    replicated) — matches the monolithic path at the f32 floor."""
    cfg = dataclasses.replace(get_smoke_config("recurrentgemma-9b"),
                              n_layers=10, dtype="float32",
                              train_accum=2)
    mod = steps_mod.model_module(cfg)
    params = mod.init(cfg, jax.random.PRNGKey(0))
    r = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(
        r.integers(0, cfg.vocab, (4, 16)), jnp.int32)}
    micro = split_microbatches(batch, 2)
    l1, g1 = _accum_ref(cfg, params, micro, 2)
    mesh = make_pipeline_mesh(2, model=2)
    part = partition_stages(cfg, 2)
    assert part.atom == "unit" and not part.uniform
    sched = make_schedule("1f1b", 2, 2)
    fn = make_pipeline_grads_fn(cfg, part, sched, mesh)
    with jax.set_mesh(mesh):
        l2, g2 = jax.jit(fn)(params, micro)
    assert abs(float(l1) - float(l2)) / abs(float(l1)) < 1e-5
    _assert_grads_close(g1, g2)


@pytest.mark.multidevice
def test_pp2_nonuniform_whisper_grads():
    """Whisper enc-dec end-to-end: the concatenated [enc|dec] channel
    on a (stage=2, data=2) mesh, encoder atoms on the leading stage,
    decoders trailing, padded+masked stacks — matches the monolithic
    encode+decode path at the f32 floor."""
    cfg = dataclasses.replace(get_smoke_config("whisper-tiny"),
                              dtype="float32", train_accum=2)
    mod = steps_mod.model_module(cfg)
    params = mod.init(cfg, jax.random.PRNGKey(0))
    r = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(
            r.integers(0, cfg.vocab, (4, 16)), jnp.int32),
        "enc_embeds": jnp.asarray(
            r.normal(size=(4, 12, cfg.d_model)), jnp.float32),
    }
    micro = split_microbatches(batch, 2)
    l1, g1 = _accum_ref(cfg, params, micro, 2)
    mesh = make_pipeline_mesh(2)
    part = partition_stages(cfg, 2)
    assert part.atom == "encdec" and not part.uniform
    sched = make_schedule("1f1b", 2, 2)
    fn = make_pipeline_grads_fn(cfg, part, sched, mesh)
    with jax.set_mesh(mesh):
        l2, g2 = jax.jit(fn)(params, micro)
    assert abs(float(l1) - float(l2)) / abs(float(l1)) < 1e-5
    _assert_grads_close(g1, g2)


@pytest.mark.skipif("jax.device_count() < 8")
def test_4d_pp2_dp2_mp2_grads():
    """The full 4D composition on 8 devices: (stage=2, data=2,
    model=2) — pipeline x data x tensor parallelism in one program —
    matches the meshless baseline at the f32 floor. Runs only in the
    8-device subprocess (see the smoke below)."""
    cfg, params, specs, batches = _setup()
    micro = split_microbatches(batches[0], M)
    l1, g1 = _accum_ref(cfg, params, micro, M)
    mesh = make_pipeline_mesh(2, model=2)
    assert dict(mesh.shape) == {"stage": 2, "data": 2, "model": 2}
    l2, g2 = _pipeline_grads(cfg, params, micro, mesh, M,
                             require_uniform=True)
    assert abs(float(l1) - float(l2)) < 1e-5
    _assert_grads_close(g1, g2)


@pytest.mark.multidevice
def test_pp2_trajectory_matches_pp1_both_schedules():
    """pp=2 gpipe/1f1b vs pp=1 over 20 steps: tight while rounding
    noise hasn't amplified, bounded after; gpipe == 1f1b throughout."""
    cfg, params, specs, batches = _setup()
    l1 = _run_traj(cfg, params, specs, batches,
                   jax.jit(steps_mod.make_train_step(cfg, KCFG)))
    assert np.isfinite(l1).all()

    mesh = make_pipeline_mesh(2)
    got = {}
    for kind in ("gpipe", "1f1b"):
        with jax.set_mesh(mesh):
            step = jax.jit(steps_mod.make_pipeline_step(
                cfg, KCFG, mesh=mesh, pp=2, schedule=kind, n_micro=M))
            got[kind] = _run_traj(cfg, params, specs, batches, step)
        np.testing.assert_allclose(l1[:8], got[kind][:8], rtol=2e-4)
        np.testing.assert_allclose(l1, got[kind], rtol=2e-2)
    # schedule independence: the two pipelines agree with each other
    np.testing.assert_allclose(got["gpipe"], got["1f1b"], rtol=1e-6)


@pytest.mark.multidevice
def test_pp2_moe_and_ssm_one_step():
    """Families beyond dense run through the pipeline: ssm matches at
    the fp32 floor; MoE at capacity-rounding (the stage program
    dispatches per data-shard tokens — the EP fast path's per-device
    capacity semantics — vs the meshless reference's global pool)."""
    mesh = make_pipeline_mesh(2)
    for arch, tol in (("falcon-mamba-7b", 1e-5),
                      ("moonshot-v1-16b-a3b", 2e-2)):
        cfg = dataclasses.replace(get_smoke_config(arch),
                                  dtype="float32", train_accum=2)
        mod = steps_mod.model_module(cfg)
        params = mod.init(cfg, jax.random.PRNGKey(0))
        specs = steps_mod.kfac_specs(cfg)
        r = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(
            r.integers(0, cfg.vocab, (4, 16)), jnp.int32)}
        st = TrainState(params, kfac_mod.init(params, specs, KCFG))
        _, m1 = jax.jit(steps_mod.make_train_step(cfg, KCFG))(st, batch)
        st = TrainState(params, kfac_mod.init(params, specs, KCFG))
        with jax.set_mesh(mesh):
            step = jax.jit(steps_mod.make_pipeline_step(
                cfg, KCFG, mesh=mesh, pp=2, schedule="1f1b",
                n_micro=2))
            _, m2 = step(st, batch)
        rel = abs(float(m1["loss"]) - float(m2["loss"])) \
            / abs(float(m1["loss"]))
        assert rel < tol, (arch, rel)


@pytest.mark.multidevice
def test_pp2_train_cli_smoke(tmp_path):
    """End-to-end KFACProgram wiring: --pp 2 + async-inv (bubble
    refresh) through the fault-tolerant loop."""
    from repro.launch.train import main

    summary = main(["--arch", "qwen1.5-0.5b", "--smoke", "--steps",
                    "6", "--batch", "8", "--seq", "32", "--pp", "2",
                    "--pp-schedule", "gpipe", "--async-inv",
                    "--ckpt-dir", str(tmp_path / "ck")])
    assert summary["steps"] == 6
    hist = summary["history"]
    assert any("pp_bubble_fraction" in h for h in hist)
    assert all(np.isfinite(h["loss"]) for h in hist if "loss" in h)


@pytest.mark.skipif(jax.device_count() >= 4,
                    reason="marked tests already run in this session")
def test_multidevice_subprocess_smoke(multidev_runner):
    """Tier-1 coverage of the marked tests: re-run them in a child
    process with a forced 4-device host platform."""
    proc = multidev_runner(
        ["-m", "multidevice", "tests/test_pipeline_multidev.py"])
    tail = (proc.stdout + proc.stderr)[-3000:]
    assert proc.returncode == 0, tail
    assert "passed" in proc.stdout, tail
    assert "skipped" not in proc.stdout, tail


@pytest.mark.skipif(jax.device_count() >= 8,
                    reason="8-device session runs the 4D test directly")
def test_8dev_4d_subprocess_smoke(multidev_runner):
    """Tier-1 coverage of the full (stage=2, data=2, model=2) program:
    run the 4D parity test in a child with 8 forced devices."""
    proc = multidev_runner(
        ["tests/test_pipeline_multidev.py::test_4d_pp2_dp2_mp2_grads"],
        ndev=8)
    tail = (proc.stdout + proc.stderr)[-3000:]
    assert proc.returncode == 0, tail
    assert "1 passed" in proc.stdout, tail
