"""repro.obs telemetry spine: registry semantics, batched device taps
(one device_get per drain; tapped steps bitwise-identical), span
nesting + Chrome-trace schema, exporter round-trips, and the --obs CLI
surfaces on both launchers.

The multidevice-marked test rides the same subprocess pattern as
``test_dist_solve_multidev``: tap drains must behave identically when
the tapped metrics are produced on a >1-device mesh.
"""

import json
import math
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.obs import (
    NULL,
    Counter,
    Histogram,
    JsonlWriter,
    MetricsRegistry,
    Observability,
    TapBuffer,
    Tracer,
    console_summary,
    from_args,
    prometheus_text,
    with_taps,
)


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_counter_monotonic():
    reg = MetricsRegistry()
    c = reg.counter("x_total", "help text")
    c.inc()
    c.inc(2.5)
    assert c.value() == 3.5
    with pytest.raises(ValueError):
        c.inc(-1)
    assert c.value() == 3.5          # failed inc left no trace


def test_counter_label_isolation():
    c = Counter("req_total")
    c.inc(reason="eos")
    c.inc(3, reason="length")
    c.inc(reason="eos")
    assert c.value(reason="eos") == 2
    assert c.value(reason="length") == 3
    assert c.value(reason="nope") == 0
    rows = {tuple(sorted(r["labels"].items())): r["value"]
            for r in c._sample_rows()}
    assert rows[(("reason", "eos"),)] == 2


def test_gauge_last_write_wins():
    reg = MetricsRegistry()
    g = reg.gauge("depth")
    g.set(4)
    g.set(2)
    g.inc()
    assert g.value() == 3
    assert g.value(shard="a") is None
    g.set(9, shard="a")
    assert g.value(shard="a") == 9
    assert g.value() == 3            # labelless series untouched


def test_histogram_bucket_edges_le_semantics():
    h = Histogram("lat", buckets=(1.0, 2.0, 4.0))
    for v in (1.0, 1.5, 2.0, 4.0, 5.0, 100.0):
        h.observe(v)
    row = h._sample_rows()[0]
    # cumulative le semantics: le=1 covers {1.0}; le=2 adds {1.5, 2.0};
    # le=4 adds {4.0}; +Inf adds {5.0, 100.0}
    assert row["buckets"]["1.0"] == 1
    assert row["buckets"]["2.0"] == 3
    assert row["buckets"]["4.0"] == 4
    assert row["buckets"]["+Inf"] == 6
    assert row["count"] == 6
    assert row["sum"] == pytest.approx(113.5)


def test_histogram_quantile_and_empty():
    h = Histogram("lat", buckets=(1.0, 2.0, 4.0))
    assert math.isnan(h.quantile(0.5))
    for _ in range(10):
        h.observe(1.5)
    q = h.quantile(0.5)
    assert 1.0 <= q <= 2.0           # interpolated inside its bucket
    h2 = Histogram("big", buckets=(1.0,))
    h2.observe(50.0)                 # +Inf bucket -> last finite edge
    assert h2.quantile(0.99) == 1.0


def test_registry_get_or_create_and_mismatch():
    reg = MetricsRegistry()
    a = reg.counter("n")
    assert reg.counter("n") is a     # idempotent handle
    with pytest.raises(TypeError):
        reg.gauge("n")
    reg.histogram("h", buckets=(1, 2))
    with pytest.raises(ValueError):
        reg.histogram("h", buckets=(1, 2, 3))
    assert "n" in reg and len(reg) == 2
    assert reg.names() == ["h", "n"]


def test_registered_but_untouched_counter_exports_zero():
    reg = MetricsRegistry()
    reg.counter("quiet_total", "never incremented")
    snap = reg.snapshot()
    assert snap[0]["samples"] == [{"labels": {}, "value": 0.0}]
    assert "quiet_total 0" in prometheus_text(reg)


# ---------------------------------------------------------------------------
# device taps
# ---------------------------------------------------------------------------

def test_tapbuffer_single_device_get_per_drain(monkeypatch):
    calls = []
    real = jax.device_get

    def counting(x):
        calls.append(1)
        return real(x)

    monkeypatch.setattr(jax, "device_get", counting)
    buf = TapBuffer()
    expect = {}
    for step in range(5):
        m = {"loss": jnp.asarray(step * 1.5),
             "gnorm": jnp.asarray(step + 0.25),
             "aux": jnp.asarray(step, jnp.int32)}
        expect[step] = {k: float(v) for k, v in m.items()}
        buf.push(step, m)
    calls.clear()                    # float() above also syncs; ignore
    assert len(buf) == 5
    rows = buf.drain()
    assert len(calls) == 1           # ONE batched transfer for 15 scalars
    assert len(buf) == 0 and buf.n_drains == 1
    assert dict(rows) == expect
    assert buf.drain() == [] and buf.n_drains == 1   # empty: no sync


def test_tapbuffer_clear_drops_without_reading(monkeypatch):
    def boom(x):
        raise AssertionError("clear must not touch the device")

    buf = TapBuffer()
    buf.push(0, {"m": jnp.asarray(1.0)})
    monkeypatch.setattr(jax, "device_get", boom)
    buf.clear()
    assert len(buf) == 0
    assert buf.drain() == []         # nothing buffered -> no device_get


def test_with_taps_bitwise_parity():
    def step(state, batch):
        w = state["w"] + batch.sum(axis=0)
        return {"w": w, "t": state["t"] + 1}, {"loss": (w * w).sum()}

    taps = {"w_norm": lambda st, m: jnp.sqrt((st["w"] ** 2).sum()),
            "loss_sq": lambda st, m: m["loss"] ** 2}
    base = jax.jit(step)
    tapped = jax.jit(with_taps(step, taps))
    state0 = {"w": jnp.arange(8, dtype=jnp.float32) / 7.0,
              "t": jnp.asarray(0, jnp.int32)}
    batch = jax.random.normal(jax.random.PRNGKey(0), (4, 8))
    s_base, m_base = base(state0, batch)
    s_tap, m_tap = tapped(state0, batch)
    for a, b in zip(jax.tree.leaves(s_base), jax.tree.leaves(s_tap)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert float(m_tap["loss"]) == float(m_base["loss"])
    assert set(m_tap) == {"loss", "w_norm", "loss_sq"}
    assert float(m_tap["w_norm"]) == pytest.approx(
        float(jnp.sqrt((s_base["w"] ** 2).sum())))


def test_with_taps_collision_raises():
    def step(state, batch):
        return state, {"loss": jnp.asarray(0.0)}

    tapped = with_taps(step, {"loss": lambda st, m: m["loss"]})
    with pytest.raises(ValueError, match="collides"):
        tapped({}, jnp.zeros(1))


# ---------------------------------------------------------------------------
# span tracing
# ---------------------------------------------------------------------------

def test_span_nesting_and_chrome_schema():
    tr = Tracer()
    with tr.span("outer", args={"step": 1}):
        with tr.span("inner"):
            pass
    tr.instant("marker", args={"k": 2})
    doc = tr.to_chrome()
    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    assert [e["name"] for e in evs] == ["inner", "outer", "marker"]
    inner, outer, marker = evs
    for e in (inner, outer):
        assert e["ph"] == "X"
        assert {"name", "cat", "ph", "ts", "dur", "pid", "tid",
                "args"} <= set(e)
    assert marker["ph"] == "i"
    # nesting: inner's [ts, ts+dur] lies inside outer's
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1e-6
    json.dumps(doc)                  # serializable as-is


def test_span_fence_blocks_and_cat_defaults():
    tr = Tracer()
    x = jnp.ones((64, 64))
    with tr.span("dispatch_only"):
        y = x @ x
    with tr.span("fenced", fence=lambda: y):
        y = y @ x
    evs = tr.to_chrome()["traceEvents"]
    assert evs[0]["cat"] == "dispatch"
    assert evs[1]["cat"] == "compute"


def test_span_error_recorded_and_reraised():
    tr = Tracer()
    with pytest.raises(RuntimeError):
        with tr.span("boom", fence=lambda: 1 / 0):   # fence skipped
            raise RuntimeError("inner failure")
    ev = tr.to_chrome()["traceEvents"][0]
    assert ev["name"] == "boom"
    assert ev["args"]["error"] == "RuntimeError"


def test_tracer_bounded_and_disabled():
    tr = Tracer(max_events=2)
    for i in range(5):
        with tr.span(f"s{i}"):
            pass
    assert len(tr) == 2 and tr.n_dropped == 3
    assert tr.to_chrome()["otherData"]["n_dropped"] == 3
    off = Tracer(enabled=False)
    with off.span("x"):
        pass
    off.instant("y")
    assert len(off) == 0


def test_tracer_save_roundtrip(tmp_path):
    tr = Tracer()
    with tr.span("a"):
        pass
    p = tr.save(str(tmp_path / "trace.json"))
    doc = json.load(open(p))
    assert doc["traceEvents"][0]["name"] == "a"


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------

def test_jsonl_rotation_roundtrip(tmp_path):
    path = str(tmp_path / "ev.jsonl")
    with JsonlWriter(path, max_bytes=200) as w:
        for i in range(12):
            w.write({"kind": "step", "i": i})
    assert os.path.exists(path + ".1")
    got = []
    for p in (path + ".1", path):
        got += [json.loads(line)["i"] for line in open(p)]
    # single-generation rotation: the tail of the stream is intact and
    # in order (older overwritten generations may be gone)
    assert got == sorted(got)
    assert got[-1] == 11
    w2 = JsonlWriter(path)           # reopen appends, not truncates
    w2.write({"kind": "late", "i": 12})
    w2.close()
    assert json.loads(open(path).readlines()[-1])["i"] == 12


def test_prometheus_text_format():
    reg = MetricsRegistry()
    reg.counter("req_total", "requests").inc(3, mode="paged")
    h = reg.histogram("lat_s", "latency", buckets=(0.1, 1.0))
    h.observe(0.05)
    h.observe(0.5)
    h.observe(5.0)
    text = prometheus_text(reg)
    assert "# HELP req_total requests" in text
    assert "# TYPE req_total counter" in text
    assert 'req_total{mode="paged"} 3' in text
    assert '# TYPE lat_s histogram' in text
    assert 'lat_s_bucket{le="0.1"} 1' in text
    assert 'lat_s_bucket{le="1"} 2' in text
    assert 'lat_s_bucket{le="+Inf"} 3' in text
    assert "lat_s_count 3" in text
    assert "lat_s_sum" in text


def test_console_summary_renders():
    reg = MetricsRegistry()
    reg.counter("n_total").inc(7)
    reg.histogram("t_s", buckets=(1.0, 2.0)).observe(1.5, phase="wu")
    out = console_summary(reg, title="t")
    assert "== t ==" in out
    assert "n_total" in out and "7" in out
    assert 'phase="wu"' in out and "p99=" in out


# ---------------------------------------------------------------------------
# facade
# ---------------------------------------------------------------------------

def test_null_obs_is_inert(tmp_path):
    assert not NULL.enabled
    c = NULL.counter("x_total")      # handles still work (never exported)
    c.inc()
    with NULL.span("s"):
        pass
    NULL.event("e", a=1)
    NULL.write({"kind": "r"})
    assert NULL.flush() == {}
    assert len(NULL.tracer) == 0
    assert list(tmp_path.iterdir()) == []


def test_observability_flush_writes_all_artifacts(tmp_path):
    o = Observability(out_dir=str(tmp_path / "obs"))
    o.counter("a_total").inc()
    with o.span("s"):
        pass
    o.event("ev", x=1)
    paths = o.flush(summary={"kind": "run_summary", "n": 3})
    o.close()
    assert set(paths) == {"jsonl", "prom", "trace"}
    lines = [json.loads(l) for l in open(paths["jsonl"])]
    assert lines[0]["kind"] == "ev"
    assert lines[-1] == {**lines[-1], "kind": "run_summary",
                         "schema": 1, "n": 3}
    assert "a_total 1" in open(paths["prom"]).read()
    names = [e["name"] for e in
             json.load(open(paths["trace"]))["traceEvents"]]
    assert names == ["s", "ev"]


def test_from_args():
    class A:
        obs = False
        obs_dir = None

    assert from_args(A()) is NULL
    a = A()
    a.obs = True
    o = from_args(a)
    assert o.enabled and o.out_dir is None
    b = A()
    b.obs_dir = "/tmp/nonexistent-not-created-until-init"


# ---------------------------------------------------------------------------
# train loop integration: batched drain + full per-step history
# ---------------------------------------------------------------------------

class _ToyProgram:
    def init_state(self, mesh):
        return {"w": jnp.zeros((4,))}

    def make_step(self, mesh):
        @jax.jit
        def step(state, batch):
            s = jnp.sum(batch["tokens"][:, 0]).astype(jnp.float32)
            return {"w": state["w"] + s}, {"loss": s, "aux": s * 2}
        return step

    def state_sharding(self, mesh):
        return lambda key: None


def _run_loop(tmp_path, obs=None, total=12, log_every=5):
    from repro.data import SyntheticTokens
    from repro.runtime import LoopConfig, TrainLoop

    ds = SyntheticTokens(vocab=97, seq_len=8, global_batch=4, seed=3)
    loop = TrainLoop(
        LoopConfig(total_steps=total, ckpt_dir=str(tmp_path / "ck"),
                   ckpt_every=50, log_every=log_every),
        _ToyProgram(), ds, obs=obs)
    return loop, loop.run()


def test_loop_history_records_every_step(tmp_path):
    loop, summary = _run_loop(tmp_path)
    # the old loop sampled the history at log_every cadence; now every
    # step's scalars are retained, formatting alone is throttled
    assert [h["step"] for h in summary["history"]] == list(range(12))
    assert all({"loss", "aux"} <= set(h) for h in summary["history"])
    # drains happen once per log window (+ the tail), not per step
    assert 1 <= loop._taps.n_drains <= 4


def test_loop_obs_on_matches_off(tmp_path):
    _, off = _run_loop(tmp_path / "a", obs=None)
    obs = Observability(out_dir=str(tmp_path / "obsout"))
    loop, on = _run_loop(tmp_path / "b", obs=obs)
    assert [h["loss"] for h in on["history"]] == \
        [h["loss"] for h in off["history"]]
    assert obs.counter("train_steps_total").value() == 12
    # every step row also landed in the JSONL stream
    paths = obs.flush()
    obs.close()
    rows = [json.loads(l) for l in open(paths["jsonl"])]
    assert sum(r["kind"] == "train_step" for r in rows) == 12


# ---------------------------------------------------------------------------
# CLI smokes
# ---------------------------------------------------------------------------

def _prom_names(path):
    names = set()
    for line in open(path):
        if line.startswith("#") or not line.strip():
            continue
        name = line.split("{")[0].split(" ")[0]
        for suffix in ("_bucket", "_sum", "_count"):
            if name.endswith(suffix):
                name = name[: -len(suffix)]
        names.add(name)
    return names


def test_train_cli_obs_smoke(tmp_path):
    from repro.launch.train import main

    obs_dir = tmp_path / "obs"
    summary = main([
        "--arch", "qwen1.5-0.5b", "--smoke", "--steps", "4",
        "--batch", "2", "--seq", "16", "--smw",
        "--ckpt-dir", str(tmp_path / "ck"),
        "--obs-dir", str(obs_dir)])
    assert summary["steps"] == 4
    assert len(summary["history"]) == 4      # every step recorded
    names = _prom_names(obs_dir / "metrics.prom")
    need = {"train_steps_total", "train_step_wall_s", "train_phase_s",
            "train_loss", "solve_smw_drift", "solve_smw_fallback_total",
            "runtime_remesh_total"}
    assert need <= names, f"missing {need - names}"
    doc = json.load(open(obs_dir / "trace.json"))
    assert any(e["name"].startswith("phase:")
               for e in doc["traceEvents"])
    kinds = [json.loads(l)["kind"]
             for l in open(obs_dir / "events.jsonl")]
    assert kinds.count("train_step") == 4
    assert "train_summary" in kinds


def test_serve_cli_obs_smoke(tmp_path):
    from repro.launch.serve import main

    obs_dir = tmp_path / "obs"
    summary, done = main([
        "--arch", "qwen2-0.5b", "--smoke", "--paged", "--prefix-cache",
        "--requests", "6", "--max-slots", "2", "--prompt-len", "16",
        "--gen", "6", "--kv-blocks", "6",
        "--obs-dir", str(obs_dir)])
    assert summary["schema"] == 1
    assert summary["kind"] == "serve_summary"
    assert "scheduler" in summary and "resident_bytes" in summary
    names = _prom_names(obs_dir / "metrics.prom")
    need = {"serve_ttft_s", "serve_tpot_s", "serve_queue_depth",
            "serve_slot_occupancy", "serve_free_blocks",
            "serve_prefix_hits_total", "serve_preemptions_total",
            "serve_requests_total"}
    assert need <= names, f"missing {need - names}"
    rows = [json.loads(l) for l in open(obs_dir / "events.jsonl")]
    fin = [r for r in rows if r["kind"] == "request_finished"]
    assert len(fin) == 6
    assert rows[-1]["kind"] == "serve_summary"
    assert rows[-1]["schema"] == 1
    doc = json.load(open(obs_dir / "trace.json"))
    assert any(e["name"] == "decode_chunk" for e in doc["traceEvents"])


# ---------------------------------------------------------------------------
# multidevice: tap drain over a sharded step (subprocess pattern)
# ---------------------------------------------------------------------------

@pytest.mark.multidevice
def test_tap_drain_multidevice_parity():
    """Tapped metrics produced by a sharded program drain to the same
    host floats a per-metric blocking readback would give, and the
    tapped step's (sharded) state is bitwise the untapped one."""
    mesh = jax.make_mesh((4,), ("data",))
    sh = jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec("data"))

    def step(state, batch):
        w = state + batch.sum(axis=0)
        return w, {"loss": (w * w).sum(), "mean": w.mean()}

    tapped = jax.jit(
        with_taps(step, {"norm": lambda st, m: jnp.sqrt(
            (st * st).sum())}))
    base = jax.jit(step)
    state = jax.device_put(jnp.arange(8, dtype=jnp.float32), sh)
    batch = jax.device_put(
        jnp.ones((2, 8), jnp.float32), jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec(None, "data")))

    buf = TapBuffer()
    s_t = state
    s_b = state
    expect = []
    for i in range(3):
        s_b, m_b = base(s_b, batch)
        s_t, m_t = tapped(s_t, batch)
        expect.append({k: float(v) for k, v in m_b.items()})
        buf.push(i, m_t)
    np.testing.assert_array_equal(np.asarray(s_b), np.asarray(s_t))
    rows = buf.drain()
    assert buf.n_drains == 1
    for (tag, m), e, i in zip(rows, expect, range(3)):
        assert tag == i
        assert m["loss"] == e["loss"] and m["mean"] == e["mean"]
        assert m["norm"] == pytest.approx(math.sqrt(m["loss"]))


def test_multidevice_subprocess_smoke(multidev_runner):
    res = multidev_runner(["-m", "multidevice", "tests/test_obs.py"])
    assert res.returncode == 0, res.stdout + res.stderr
    assert "1 passed" in res.stdout
