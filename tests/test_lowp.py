"""repro.lowp: the end-to-end low-precision mode.

Three layers of contract:
* ``lowp_einsum`` — the routing primitive every WU matmul goes
  through (fp32 must stay *bitwise* the historical einsum; hilo/int
  modes carry an accuracy budget);
* ``update_parity`` — the ROADMAP acceptance number: >= 16 effective
  bits on the preconditioned update at ``--precision hilo|int8``;
* ``serve_quant`` — int8 resident weights + KV codes: exact embedding
  skip, code-stable requantization, byte accounting.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.quantize import (
    hilo_einsum,
    int_slice_einsum,
    lowp_einsum,
    precision_kind,
)
from repro.lowp import serve_quant
from repro.lowp.serve_quant import QTensor


def _ab(m=64, k=96, n=48, seed=0):
    rng = np.random.default_rng(seed)
    return (jnp.asarray(rng.standard_normal((m, k)), jnp.float32),
            jnp.asarray(rng.standard_normal((k, n)), jnp.float32))


def _bits(out, ref):
    err = np.max(np.abs(np.asarray(out, np.float64)
                        - np.asarray(ref, np.float64)))
    return -np.log2(err / np.max(np.abs(np.asarray(ref, np.float64))))


class TestPrecisionSpec:
    def test_kinds(self):
        assert precision_kind("fp32") == "fp32"
        assert precision_kind("hilo") == "hilo"
        assert precision_kind("int8") == (24, 8)  # shipped alias
        assert precision_kind("int16b4") == (16, 4)
        assert precision_kind("int4b4") == (4, 4)

    @pytest.mark.parametrize("bad", ["fp16", "int8b", "intxby", "",
                                     "int0b4", "int8b0", "int4b8"])
    def test_rejects(self, bad):
        with pytest.raises(ValueError):
            precision_kind(bad)


class TestLowpEinsum:
    def test_fp32_is_bitwise_the_historical_path(self):
        a, b = _ab()
        ref = jnp.einsum("mk,kn->mn", a, b,
                         preferred_element_type=jnp.float32)
        out = lowp_einsum("mk,kn->mn", a, b, precision="fp32")
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))

    def test_hilo_budget(self):
        a, b = _ab(seed=1)
        ref = a @ b
        assert _bits(hilo_einsum("mk,kn->mn", a, b), ref) >= 20.0

    def test_int8_budget_and_ladder_order(self):
        a, b = _ab(seed=2)
        ref = a @ b
        bits = {p: _bits(lowp_einsum("mk,kn->mn", a, b, precision=p),
                         ref)
                for p in ("int4b4", "int8b4", "int16b4", "int8")}
        assert bits["int8"] >= 18.0          # 24-bit codes
        assert bits["int16b4"] > bits["int8b4"] > bits["int4b4"]

    def test_int_slice_exact_in_quantized_codes(self):
        """Slice composition is *exact* in the quantized codes (the
        ISAAC argument): the sliced product equals the full product of
        the quantized operands — the only error in the mode is the
        operand quantization itself, never the composition."""
        from repro.core.quantize import amax_scale, quantize_fixed

        rng = np.random.default_rng(3)
        a = jnp.asarray(rng.standard_normal((16, 24)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((24, 8)), jnp.float32)
        out = int_slice_einsum("mk,kn->mn", a, b,
                               total_bits=8, slice_bits=4)
        aq = quantize_fixed(a, 8, amax_scale(a))
        bq = quantize_fixed(b, 8, amax_scale(b))
        ref = np.asarray(aq, np.float64) @ np.asarray(bq, np.float64)
        # composition is exact in the codes; the only residue is fp32
        # rounding of the sa*sb rescale (~2**-23 of the output range)
        np.testing.assert_allclose(
            np.asarray(out), ref, rtol=0,
            atol=float(np.max(np.abs(ref))) * 2.0 ** -19)

    def test_batched_spec(self):
        rng = np.random.default_rng(4)
        a = jnp.asarray(rng.standard_normal((3, 8, 16)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((3, 16, 4)), jnp.float32)
        ref = jnp.einsum("nab,nbc->nac", a, b)
        for p in ("hilo", "int8"):
            out = lowp_einsum("nab,nbc->nac", a, b, precision=p)
            assert out.shape == ref.shape
            assert _bits(out, ref) >= 16.0


class TestUpdateParity:
    """The acceptance criterion: >= 16 effective bits on the
    preconditioned update vs the fp32 reference, from a warmed
    (non-identity-inverse) state, on the smoke arch."""

    @pytest.mark.parametrize("precision", ["hilo", "int8"])
    def test_min_bits_budget(self, precision):
        from repro.lowp import update_parity

        r = update_parity(precision)
        assert r["min_bits"] >= 16.0, r

    def test_kernel_path_rejects_int_modes(self):
        """The Pallas kernel IS the hilo scheme — integer-sliced modes
        cannot compose with use_kernel and must fail loudly, not fall
        back silently to a different precision."""
        from repro.core import kfac

        with pytest.raises(ValueError, match="use_kernel"):
            kfac.precondition_pooled({}, {}, None, use_kernel=True,
                                     precision="int8")
        with pytest.raises(ValueError, match="use_kernel"):
            kfac.precondition_pooled({}, {}, None, use_kernel=True,
                                     precision="int16b4")


class TestServeQuant:
    def test_qtensor_roundtrip_codes(self):
        rng = np.random.default_rng(5)
        w = jnp.asarray(rng.standard_normal((32, 16)), jnp.float32)
        qt = serve_quant._encode(w, axis=-2)
        assert qt.q.dtype == jnp.int8
        w2 = qt.q.astype(jnp.float32) * qt.scale
        # dequant -> re-encode recovers the same codes (code-stable)
        qt2 = serve_quant._encode(w2, axis=-2)
        np.testing.assert_array_equal(np.asarray(qt.q),
                                      np.asarray(qt2.q))
        # and the dequant error is within half a quantization step
        step = np.asarray(qt.scale)
        assert np.all(np.abs(np.asarray(w2 - w)) <= step / 2 + 1e-7)

    def test_quantize_params_skips_embed_and_vectors(self):
        params = {
            "embed": jnp.ones((8, 4)),
            "layers": {"wq": jnp.ones((4, 4)), "ln1": jnp.ones((4,))},
        }
        q = serve_quant.quantize_params(params)
        assert not isinstance(q["embed"], QTensor)
        assert not isinstance(q["layers"]["ln1"], QTensor)
        assert isinstance(q["layers"]["wq"], QTensor)
        d = serve_quant.dequantize_params(q)
        np.testing.assert_allclose(np.asarray(d["layers"]["wq"]),
                                   np.ones((4, 4)), atol=1e-6)

    def test_zero_leaf_safe(self):
        q = serve_quant.quantize_params({"w": jnp.zeros((4, 4))})
        d = serve_quant.dequantize_params(q)
        np.testing.assert_array_equal(np.asarray(d["w"]),
                                      np.zeros((4, 4)))

    def test_kv_roundtrip_and_code_stability(self):
        rng = np.random.default_rng(6)
        pool = {"layers": {
            "k": jnp.asarray(rng.standard_normal((2, 3, 4, 8, 5)),
                             jnp.bfloat16),
            "v": jnp.asarray(rng.standard_normal((2, 3, 4, 8, 5)),
                             jnp.bfloat16),
            "pos": jnp.zeros((3, 8), jnp.int32)},
            "idx": jnp.zeros((3,), jnp.int32)}
        q = serve_quant.quantize_kv(pool)
        assert q["layers"]["k"].dtype == jnp.int8
        assert q["layers"]["k_scale"].shape == (2, 3, 4, 8)
        assert q["layers"]["pos"].dtype == jnp.int32
        f = serve_quant.dequantize_kv(q)
        assert "k_scale" not in f["layers"]
        # fp32 dequant -> requant keeps every code (decode chunks must
        # not drift rows they didn't write)
        q2 = serve_quant.requantize_kv(f, like=q)
        np.testing.assert_array_equal(np.asarray(q2["layers"]["k"]),
                                      np.asarray(q["layers"]["k"]))
        np.testing.assert_array_equal(np.asarray(q2["layers"]["v"]),
                                      np.asarray(q["layers"]["v"]))
        # dtype contract restored for non-KV leaves
        assert q2["layers"]["pos"].dtype == jnp.int32
        # dequantizing an already-float pool is the identity
        same = serve_quant.dequantize_kv(pool)
        assert same["layers"]["k"] is pool["layers"]["k"]

    def test_requantize_dirty_mask_pins_clean_entries(self):
        """Dirty-masked requant (the paged engine's per-chunk path):
        entries of axis 1 outside the mask keep their codes AND scales
        bitwise from the resident pool — even if the float input
        drifted — while masked entries re-encode from the input."""
        rng = np.random.default_rng(7)
        pool = {"layers": {
            "k": jnp.asarray(rng.standard_normal((2, 3, 4, 8, 5)),
                             jnp.bfloat16),
            "v": jnp.asarray(rng.standard_normal((2, 3, 4, 8, 5)),
                             jnp.bfloat16),
            "pos": jnp.zeros((3, 8), jnp.int32)},
            "idx": jnp.zeros((3,), jnp.int32)}
        q = serve_quant.quantize_kv(pool)
        # perturb EVERY entry of the float pool, then requantize with
        # only entry 1 marked dirty
        bump = {"layers": dict(
            pool["layers"],
            k=pool["layers"]["k"] * jnp.bfloat16(1.5),
            v=pool["layers"]["v"] * jnp.bfloat16(1.5)),
            "idx": pool["idx"]}
        dirty = jnp.asarray([False, True, False])
        q2 = serve_quant.requantize_kv(bump, like=q, dirty=dirty)
        q_full = serve_quant.quantize_kv(bump)
        for leaf in ("k", "v", "k_scale", "v_scale"):
            new = np.asarray(q2["layers"][leaf])
            # clean entries: bitwise the resident codes/scales
            np.testing.assert_array_equal(
                new[:, [0, 2]], np.asarray(q["layers"][leaf])[:, [0, 2]])
            # dirty entry: a fresh encode of the perturbed values
            np.testing.assert_array_equal(
                new[:, 1], np.asarray(q_full["layers"][leaf])[:, 1])

    def test_tree_bytes(self):
        t = {"a": jnp.zeros((4, 4), jnp.float32),
             "b": QTensor(jnp.zeros((4, 4), jnp.int8),
                          jnp.zeros((1, 4), jnp.float32))}
        assert serve_quant.tree_bytes(t) == 64 + 16 + 16
