"""MoE datapath equivalence: the shard_map fast path (EP dispatch, one
psum) must match the reference global-scatter path — same top-k, same
capacity-union semantics — and both must drop overflow tokens
identically when capacity binds."""

import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models import lm, moe


def _mesh(shape=(2, 2)):
    if jax.device_count() < shape[0] * shape[1]:
        pytest.skip(f"needs {shape[0] * shape[1]} devices "
                    f"(run under --xla_force_host_platform_device_count)")
    return jax.make_mesh(
        shape, ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)


def test_fast_path_selection():
    cfg = get_smoke_config("phi3.5-moe-42b-a6.6b")
    from repro.models.layers import Ctx

    # no mesh -> reference
    assert not moe._use_fast_path(cfg, None, "layers/moe")
    # collect/taps -> reference even under a mesh (SU graph)
    ctx = Ctx(collect=True)
    assert not moe._use_fast_path(cfg, ctx, "layers/moe")


def test_reference_path_capacity_and_drop():
    cfg = get_smoke_config("moonshot-v1-16b-a3b")
    params = lm.init(cfg, jax.random.PRNGKey(0))
    r = np.random.default_rng(1)
    batch = {"tokens": jnp.asarray(
        r.integers(0, cfg.vocab, (2, 16)), jnp.int32)}
    loss, _ = jax.jit(lambda p, b: lm.loss_fn(cfg, p, b))(params, batch)
    assert np.isfinite(float(loss))


def test_moe_capacity_math():
    cfg = get_smoke_config("moonshot-v1-16b-a3b")
    c = moe.capacity(cfg, 1024)
    assert c >= 8 and c % 8 == 0
    expect = cfg.capacity_factor * 1024 * cfg.top_k / cfg.n_experts
    assert c >= int(expect) - 8
