"""Checkpoint store: atomicity, async manager, reshard-on-restore."""

import os
import threading

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager, latest_step, restore, save


def _tree(seed=0):
    r = np.random.default_rng(seed)
    return {
        "params": {"w": r.standard_normal((8, 16)).astype(np.float32),
                   "b": r.standard_normal(16).astype(np.float32)},
        "opt": [jnp.ones((3,)), jnp.zeros((), jnp.int32)],
    }


def test_save_restore_roundtrip(tmp_path):
    d = str(tmp_path / "ck")
    t = _tree()
    save(d, 7, t, meta={"cursor": {"step": 7}})
    assert latest_step(d) == 7
    got, manifest = restore(d, _tree(seed=1))
    assert manifest["step"] == 7
    assert manifest["meta"]["cursor"]["step"] == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_of_many_and_gc(tmp_path):
    d = str(tmp_path / "ck")
    mgr = CheckpointManager(d, keep=2)
    for s in (5, 10, 15, 20):
        mgr.save_async(s, _tree(s), meta={"cursor": {"step": s}})
    mgr.wait()
    assert latest_step(d) == 20
    kept = sorted(n for n in os.listdir(d) if n.startswith("step_"))
    assert len(kept) == 2          # gc keeps last 2


def test_atomic_no_partial_visible(tmp_path):
    """A .tmp dir must never be picked up by latest_step."""
    d = str(tmp_path / "ck")
    save(d, 1, _tree())
    os.makedirs(os.path.join(d, "step_0000000002.tmp"))
    assert latest_step(d) == 1


def test_restore_missing_leaf_raises(tmp_path):
    d = str(tmp_path / "ck")
    save(d, 1, {"a": np.ones(3)})
    with pytest.raises(KeyError):
        restore(d, {"a": np.ones(3), "extra": np.ones(2)})


def test_restore_with_sharding_fn(tmp_path):
    d = str(tmp_path / "ck")
    t = _tree()
    save(d, 3, t)
    mesh = jax.make_mesh(
        (1,), ("data",),
        axis_types=(jax.sharding.AxisType.Auto,))
    from jax.sharding import NamedSharding, PartitionSpec as P

    calls = []

    def shard_of(key, arr):
        calls.append(key)
        return NamedSharding(mesh, P())

    got, _ = restore(d, _tree(1), sharding_fn=shard_of)
    assert len(calls) == len(jax.tree.leaves(t))
    for leaf in jax.tree.leaves(got):
        assert isinstance(leaf, jax.Array)


def test_async_error_surfaces(tmp_path):
    mgr = CheckpointManager("/proc/definitely/not/writable", keep=1)
    mgr.save_async(1, {"a": np.ones(2)})
    with pytest.raises(BaseException):
        mgr.wait()
